"""Run the full (architecture × input-shape × mesh) dry-run matrix and save
one JSON per combo into results/dryrun/ (resumable; skips existing files).

  PYTHONPATH=src python -m benchmarks.dryrun_sweep [--multi-pod-only] [--redo]

``--quick`` is the CI smoke mode: one small architecture × the training
shape on the single-pod mesh, with an aggregate ``--summary`` JSON suitable
for artifact upload.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import gc
import json
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCH_IDS
from repro.configs.shapes import SHAPES

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

QUICK_ARCHS = ["qwen1.5-0.5b"]
QUICK_SHAPES = ["train_4k"]


def combo_path(arch, shape, multi_pod, suffix=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--redo", action="store_true")
    ap.add_argument("--only-mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--archs", default="")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: one small arch x train_4k, single mesh")
    ap.add_argument("--summary", default="",
                    help="write an aggregate JSON of every combo run")
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun

    os.makedirs(OUT_DIR, exist_ok=True)
    archs = args.archs.split(",") if args.archs else ARCH_IDS
    shapes = list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.only_mesh]
    if args.quick:
        archs = args.archs.split(",") if args.archs else QUICK_ARCHS
        shapes = QUICK_SHAPES
        meshes = [False]
    failures = []
    summary = {"quick": args.quick, "combos": []}
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                path = combo_path(arch, shape, multi_pod)
                tag = f"{arch} x {shape} x {'2x16x16' if multi_pod else '16x16'}"
                if os.path.exists(path) and not args.redo:
                    if args.summary:
                        with open(path) as f:
                            summary["combos"].append(json.load(f))
                    continue
                print(f"== {tag}", flush=True)
                try:
                    res = dryrun(arch, shape, multi_pod=multi_pod, verbose=False)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=2, default=str)
                    if args.summary:
                        summary["combos"].append(res)
                    if "skipped" in res:
                        print(f"   SKIP: {res['skipped'][:80]}", flush=True)
                    else:
                        print(
                            "   ok compute=%.3fs mem=%.3fs coll=%.3fs dom=%s "
                            "useful=%.2f compile=%ss" % (
                                res["compute_term_s"], res["memory_term_s"],
                                res["collective_term_s"], res["dominant_term"],
                                res["useful_flops_ratio"], res["compile_s"]),
                            flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    if args.summary:
                        summary["combos"].append(
                            {"arch": arch, "shape": shape, "failed": repr(e)})
                    print(f"   FAIL {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                gc.collect()
    print(f"sweep done; {len(failures)} failures", flush=True)
    for t, e in failures:
        print("  FAILED:", t, e[:200], flush=True)
    if args.summary:
        summary["n_failures"] = len(failures)
        os.makedirs(os.path.dirname(os.path.abspath(args.summary)),
                    exist_ok=True)
        with open(args.summary, "w") as f:
            json.dump(summary, f, indent=2, default=str)
        print(f"summary -> {args.summary}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
