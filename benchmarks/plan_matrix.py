"""Per-plan bench rows for the composed-parallelism matrix.

Runs ``Gym.bench`` (the one timing implementation) once per sharding
plan on a forced-8-device CPU mesh and writes one row per plan —
``steady_step_ms`` / ``mfu`` / ``tokens_per_s`` plus the analytic
pipeline block (``pp``, ``n_micro``, ``bubble_fraction``) — into the
tracked ``BENCH_plans.json`` at the repo root.  Absolute CPU numbers
are meaningless as GPU/TPU predictors; the row set exists so every
composed plan has a *working, timed* configuration that future PRs
re-run and diff structurally (plan string, bubble math, shard
warnings), and so relative regressions within one matrix refresh are
visible.

    PYTHONPATH=src python benchmarks/plan_matrix.py [--steps 12] [--out ...]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# (plan, arch, mesh kwargs) — every composed plan in the catalog that an
# 8-device host mesh can realize, dense and MoE
MATRIX = [
    ("ddp", "qwen1p5_0p5b", dict(dp=8, tp=1)),
    ("fsdp", "qwen1p5_0p5b", dict(dp=8, tp=1)),
    ("fsdp_tp", "qwen1p5_0p5b", dict(dp=4, tp=2)),
    ("pp2_fsdp", "qwen1p5_0p5b", dict(dp=4, tp=1, pp=2)),
    ("pp2_fsdp_tp", "qwen1p5_0p5b", dict(dp=2, tp=2, pp=2)),
    ("fsdp_tp_ep", "deepseek_moe_16b", dict(dp=4, tp=2)),
    ("pp2_fsdp_tp_ep", "deepseek_moe_16b", dict(dp=2, tp=2, pp=2)),
]


def build_arch(arch: str):
    from repro.configs import get_reduced

    cfg = get_reduced(arch)
    if cfg.moe:
        # 4 layers so both the dense prelude and the MoE stack split into
        # 2 contiguous stages (reduced default is 2 layers / 1 dense)
        return dataclasses.replace(
            cfg, n_layers=4,
            moe=dataclasses.replace(cfg.moe, n_dense_layers=2))
    return cfg


def bench_plan(plan_name: str, arch: str, mesh_kw, steps: int, warmup: int,
               global_batch: int = 8):
    import repro.core.components  # noqa: F401  (populate the registry)
    from repro.config.registry import DEFAULT_REGISTRY as REG
    from repro.core.gym import Gym
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.optim.adamw import AdamW
    from repro.sharding import plans as PL

    cfg = build_arch(arch)
    model = build_model(cfg)
    ds = REG.build("dataset", "synthetic", n_tokens=60000, vocab=cfg.vocab,
                   prefix=f"/tmp/repro_plan_matrix_{arch}", seq_len=64,
                   seed=0)
    loader = REG.build("loader", "sharded", dataset=ds,
                       global_batch=global_batch)
    gym = Gym(model=model, optimizer=AdamW(lr=1e-3), loader=loader,
              mesh=make_local_mesh(**mesh_kw), plan=PL.make_plan(plan_name),
              log_every=0, prefetch=2)
    res = gym.bench(steps=steps, warmup=warmup)
    row = {
        "plan_name": plan_name,
        "arch": cfg.name,
        "mesh": "x".join(f"{k}={v}" for k, v in mesh_kw.items()),
        "n_layers": cfg.n_layers,
    }
    for k in ("plan", "pipeline", "compile_s", "steady_step_ms",
              "steady_step_ms_mean", "mfu", "tokens_per_s", "final_loss",
              "global_batch", "seq_len"):
        if k in res:
            row[k] = res[k]
    row["shard_warnings"] = list(getattr(gym, "shard_warnings", []) or [])
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_plans.json"))
    ap.add_argument("--only", default="",
                    help="comma-separated plan names (default: all)")
    args = ap.parse_args(argv)

    only = {p for p in args.only.split(",") if p}
    rows = []
    for plan_name, arch, mesh_kw in MATRIX:
        if only and plan_name not in only:
            continue
        print(f"== {plan_name} ({arch}) ==", flush=True)
        row = bench_plan(plan_name, arch, mesh_kw, args.steps, args.warmup)
        print(json.dumps({k: row[k] for k in
                          ("plan", "steady_step_ms", "mfu", "pipeline")
                          if k in row}), flush=True)
        rows.append(row)

    out = {"devices": 8, "steps": args.steps, "warmup": args.warmup,
           "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {len(rows)} rows -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
