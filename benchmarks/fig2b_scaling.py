"""Paper Fig 2b analog: strong scaling.

Two views:
1. MEASURED — tokens/s for the same tiny model on 1/2/4/8 placeholder CPU
   devices (DDP), each in a fresh subprocess (device count locks at init).
2. MODELED — llama3-8b step time on TPU v5e as max(compute, memory,
   collective) from the analytic roofline at DP degrees 16..1024, with the
   α–β ICI collective model (this is where the paper's latency wall at
   DP=1024 shows up, and where the FSDP-unit dial recovers it).
"""
import json
import math
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# one timing implementation: the measured rows drive the `bench` run kind
# (Gym.bench) on a declarative run doc instead of a hand-rolled step loop
_MEASURE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
    import json, sys
    sys.path.insert(0, {src!r})
    from repro.run.api import execute_doc

    ndev = {ndev}
    B, S = ndev * 4, 128
    doc = {{
        "run": {{"kind": "bench", "name": f"fig2b_{{ndev}}dev",
                 "output_dir": f"/tmp/repro_fig2b_{{ndev}}dev",
                 "bench": {{"steps": 5, "warmup": 1, "bench_dir": ""}}}},
        "arch": {{"component_key": "arch_config", "variant_key": "stablelm_1p6b",
                  "config": {{"reduced": True, "n_layers": 2}}}},
        "model": {{"component_key": "model", "variant_key": "auto",
                   "config": {{"arch_config": {{"instance_key": "arch"}}}}}},
        "optimizer": {{"component_key": "optimizer", "variant_key": "adamw",
                       "config": {{"lr": 0.001}}}},
        "dataset": {{"component_key": "dataset", "variant_key": "synthetic",
                     "config": {{"n_tokens": B * (S + 1) * 16, "vocab": 512,
                                 "prefix": f"/tmp/repro_fig2b_data_{{ndev}}",
                                 "seq_len": S}}}},
        "loader": {{"component_key": "loader", "variant_key": "sharded",
                    "config": {{"dataset": {{"instance_key": "dataset"}},
                                "global_batch": B}}}},
        "mesh": {{"component_key": "mesh_provider", "variant_key": "local",
                  "config": {{"dp": ndev, "tp": 1}}}},
        "plan": {{"component_key": "sharding_plan", "variant_key": "ddp"}},
        "gym": {{"component_key": "gym", "variant_key": "standard",
                 "config": {{"model": {{"instance_key": "model"}},
                             "optimizer": {{"instance_key": "optimizer"}},
                             "loader": {{"instance_key": "loader"}},
                             "mesh_provider": {{"instance_key": "mesh"}},
                             "sharding_plan": {{"instance_key": "plan"}}}}}},
    }}
    res = execute_doc(doc, write_files=False)
    dt = res["steady_step_ms"] / 1000.0
    print(json.dumps({{"ndev": ndev, "step_s": dt,
                       "tokens_per_s": res["tokens_per_s"]}}))
""")


def measured(devices=(1, 2, 4, 8)):
    rows = []
    for n in devices:
        script = _MEASURE.format(src=SRC, ndev=n)
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-2000:])
        rows.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    base = rows[0]["tokens_per_s"]
    for r in rows:
        r["speedup"] = round(r["tokens_per_s"] / base, 2)
        r["efficiency"] = round(r["speedup"] / r["ndev"], 2)
        r["note"] = ("placeholder devices share ONE physical core: "
                     "efficiency measures framework overhead, not hardware "
                     "scaling (see the modeled_v5e rows for the TPU story)")
    return rows


# -- analytic TPU model ------------------------------------------------------
PEAK = 197e12
HBM = 819e9
BW = 50e9
ALPHA = 1e-6


def modeled_llama8b(unit_k: int = 1):
    """Step-time model for llama3-8b FSDP at growing DP degree, fixed global
    batch 1024 x 4k tokens (strong scaling)."""
    import jax

    sys.path.insert(0, SRC)
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("llama3_8b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_params = sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))
    stack = shapes["blocks"]
    layer_bytes = sum(math.prod(l.shape[1:]) * 2
                      for l in jax.tree_util.tree_leaves(stack))
    tokens_global = 1024 * 4096
    rows = []
    for dp in (16, 32, 64, 128, 256, 512, 1024):
        compute = 6 * n_params * tokens_global / dp / PEAK
        # fwd+bwd FSDP traffic: 2x all-gather + 1x reduce-scatter of params
        n_msgs = 3 * cfg.n_layers / unit_k
        msg = layer_bytes * unit_k / dp
        coll = n_msgs * (ALPHA * math.log2(dp) + msg / BW)
        mem = (18 * n_params / dp + 12 * tokens_global / dp * cfg.d_model *
               cfg.n_layers * 0.25) / HBM
        step = max(compute, coll, mem)
        rows.append({
            "dp": dp, "unit_k": unit_k,
            "compute_s": round(compute, 4),
            "collective_s": round(coll, 4),
            "memory_s": round(mem, 4),
            "step_bound": max(
                (("compute", compute), ("collective", coll), ("memory", mem)),
                key=lambda kv: kv[1])[0],
            "tokens_per_s_per_chip": int(tokens_global / dp / step),
            "ag_msg_MB": round(msg / 1e6, 3),
        })
    return rows


def run(fast: bool = False):
    out = {"modeled_llama8b_unit1": modeled_llama8b(1),
           "modeled_llama8b_unit8": modeled_llama8b(8)}
    if not fast:
        out["measured_cpu_ddp"] = measured()
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
