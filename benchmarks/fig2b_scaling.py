"""Paper Fig 2b analog: strong scaling.

Two views:
1. MEASURED — tokens/s for the same tiny model on 1/2/4/8 placeholder CPU
   devices (DDP), each in a fresh subprocess (device count locks at init).
2. MODELED — llama3-8b step time on TPU v5e as max(compute, memory,
   collective) from the analytic roofline at DP degrees 16..1024, with the
   α–β ICI collective model (this is where the paper's latency wall at
   DP=1024 shows up, and where the FSDP-unit dial recovers it).
"""
import json
import math
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_MEASURE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
    import json, sys, time
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.optim.adamw import AdamW
    from repro.sharding import plans as PL
    from repro.train import steps as ST
    from repro.launch.mesh import make_local_mesh

    cfg = get_reduced("stablelm_1p6b").with_(n_layers=2)
    model = build_model(cfg)
    opt = AdamW(lr=1e-3)
    mesh = make_local_mesh(dp={ndev}, tp=1)
    plan = PL.make_plan("ddp")
    ctx = PL.mesh_context(plan, mesh)
    rng = jax.random.PRNGKey(0)
    B, S = {ndev} * 4, 128
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {{"tokens": toks, "labels": jnp.roll(toks, -1, 1)}}
    pshapes = jax.eval_shape(model.init, rng)
    pspecs, _ = PL.param_shardings(plan, mesh, pshapes, model.param_axes())
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    state_sh = {{"params": pspecs, "opt": {{"m": pspecs, "v": pspecs,
                "count": rep}}, "step": rep}}
    with mesh:
        state = jax.jit(lambda r: ST.init_train_state(model, opt, r),
                        out_shardings=state_sh)(rng)
        step = jax.jit(ST.make_train_step(model, opt, ctx),
                       in_shardings=(state_sh, None))
        state, _ = step(state, batch)  # compile
        jax.block_until_ready(state["params"])
        t0 = time.time()
        for _ in range(5):
            state, m = step(state, batch)
        jax.block_until_ready(state["params"])
        dt = (time.time() - t0) / 5
    print(json.dumps({{"ndev": {ndev}, "step_s": dt,
                       "tokens_per_s": B * S / dt}}))
""")


def measured(devices=(1, 2, 4, 8)):
    rows = []
    for n in devices:
        script = _MEASURE.format(src=SRC, ndev=n)
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-2000:])
        rows.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    base = rows[0]["tokens_per_s"]
    for r in rows:
        r["speedup"] = round(r["tokens_per_s"] / base, 2)
        r["efficiency"] = round(r["speedup"] / r["ndev"], 2)
        r["note"] = ("placeholder devices share ONE physical core: "
                     "efficiency measures framework overhead, not hardware "
                     "scaling (see the modeled_v5e rows for the TPU story)")
    return rows


# -- analytic TPU model ------------------------------------------------------
PEAK = 197e12
HBM = 819e9
BW = 50e9
ALPHA = 1e-6


def modeled_llama8b(unit_k: int = 1):
    """Step-time model for llama3-8b FSDP at growing DP degree, fixed global
    batch 1024 x 4k tokens (strong scaling)."""
    import jax

    sys.path.insert(0, SRC)
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("llama3_8b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_params = sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))
    stack = shapes["blocks"]
    layer_bytes = sum(math.prod(l.shape[1:]) * 2
                      for l in jax.tree_util.tree_leaves(stack))
    tokens_global = 1024 * 4096
    rows = []
    for dp in (16, 32, 64, 128, 256, 512, 1024):
        compute = 6 * n_params * tokens_global / dp / PEAK
        # fwd+bwd FSDP traffic: 2x all-gather + 1x reduce-scatter of params
        n_msgs = 3 * cfg.n_layers / unit_k
        msg = layer_bytes * unit_k / dp
        coll = n_msgs * (ALPHA * math.log2(dp) + msg / BW)
        mem = (18 * n_params / dp + 12 * tokens_global / dp * cfg.d_model *
               cfg.n_layers * 0.25) / HBM
        step = max(compute, coll, mem)
        rows.append({
            "dp": dp, "unit_k": unit_k,
            "compute_s": round(compute, 4),
            "collective_s": round(coll, 4),
            "memory_s": round(mem, 4),
            "step_bound": max(
                (("compute", compute), ("collective", coll), ("memory", mem)),
                key=lambda kv: kv[1])[0],
            "tokens_per_s_per_chip": int(tokens_global / dp / step),
            "ag_msg_MB": round(msg / 1e6, 3),
        })
    return rows


def run(fast: bool = False):
    out = {"modeled_llama8b_unit1": modeled_llama8b(1),
           "modeled_llama8b_unit8": modeled_llama8b(8)}
    if not fast:
        out["measured_cpu_ddp"] = measured()
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))
