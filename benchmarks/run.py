"""Benchmark harness: one entry per paper table/figure. Prints
``name,value,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
import argparse
import time


def _csv(name, value, derived=""):
    print(f"{name},{value},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the multi-minute measured benchmarks")
    args = ap.parse_args()

    print("name,value,derived", flush=True)

    # -- hot-path bench: the ONE timing implementation (`bench` run kind) ----
    # refreshes the tracked BENCH_quickstart.json at the repo root
    import os

    from repro.config.resolver import load_yaml
    from repro.run.api import execute_doc

    t0 = time.time()
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    bench_doc = load_yaml(os.path.join(repo_root, "examples", "configs",
                                       "bench.yaml"))
    bench_doc["run"]["bench"]["steps"] = 10 if args.fast else 30
    # the tracked artifact lives at the repo root regardless of cwd
    bench_doc["run"]["bench"]["bench_dir"] = repo_root
    bres = execute_doc(bench_doc)
    _csv("bench_quickstart_compile_s", bres["compile_s"])
    _csv("bench_quickstart_steady_ms", bres["steady_step_ms"],
         f"prefetch={bres['prefetch']}")
    _csv("bench_quickstart_tok_s", bres["tokens_per_s"])
    _csv("bench_wall_s", round(time.time() - t0, 1))

    # -- Fig 2c analog: message-size latency model + FSDP unit dial ---------
    from . import fig2c_messages

    t0 = time.time()
    f2c = fig2c_messages.run()
    lat = f2c["latency_model"]
    small = next(r for r in lat if r["msg_bytes"] == 400e3)
    _csv("fig2c_effbw_0.4MB_1024ranks_GBs", small["bw_eff_1024 (GB/s)"],
         small["bound"])
    dial = f2c["fsdp_unit_dial"]["rows"]
    k1 = next(r for r in dial if r["dp"] == 1024 and r["unit_k"] == 1)
    k8 = next(r for r in dial if r["dp"] == 1024 and r["unit_k"] == 8)
    _csv("fig2c_unit1_dp1024_effbw_GBs", k1["eff_bw_GBs"], k1["bound"])
    _csv("fig2c_unit8_dp1024_effbw_GBs", k8["eff_bw_GBs"], k8["bound"])
    _csv("fig2c_wall_s", round(time.time() - t0, 1))

    # -- Fig 2b analog: strong scaling ---------------------------------------
    from . import fig2b_scaling

    t0 = time.time()
    f2b = fig2b_scaling.run(fast=args.fast)
    m1 = f2b["modeled_llama8b_unit1"]
    worst = m1[-1]
    _csv("fig2b_llama8b_dp1024_bound", worst["step_bound"],
         f"tok/s/chip={worst['tokens_per_s_per_chip']}")
    m8 = f2b["modeled_llama8b_unit8"][-1]
    _csv("fig2b_llama8b_dp1024_unit8_bound", m8["step_bound"],
         f"tok/s/chip={m8['tokens_per_s_per_chip']}")
    if "measured_cpu_ddp" in f2b:
        for r in f2b["measured_cpu_ddp"]:
            _csv(f"fig2b_measured_ddp_{r['ndev']}dev_tok_s",
                 int(r["tokens_per_s"]), f"eff={r['efficiency']}")
    _csv("fig2b_wall_s", round(time.time() - t0, 1))

    # -- tokenizer table ------------------------------------------------------
    from . import tokenizer_throughput

    t0 = time.time()
    tk = tokenizer_throughput.run(n_docs=300 if args.fast else 1500)
    _csv("tokenizer_serial_tok_s", tk["serial_tok_per_s"])
    _csv("tokenizer_pipeline_tok_s", tk["pipeline_tok_per_s"],
         f"speedup={tk['speedup']}x_on_{tk['host_cores']}core")
    _csv("tokenizer_wall_s", round(time.time() - t0, 1))

    # -- Fig 2a analog: convergence parity ------------------------------------
    if not args.fast:
        from . import fig2a_convergence

        t0 = time.time()
        f2a = fig2a_convergence.run(steps=25)
        _csv("fig2a_max_plan_divergence", round(f2a["max_divergence"], 5),
             "|".join(f2a["plans"]))
        _csv("fig2a_converged", f2a["converged"])
        _csv("fig2a_wall_s", round(time.time() - t0, 1))

    # -- roofline table (from dry-run artifacts, if present) ------------------
    try:
        from . import roofline

        rows = [roofline.fmt_row(r) for r in roofline.load("16x16")]
        ok = [r for r in rows if r]
        _csv("roofline_pairs_baselined", len(ok), "single-pod 16x16")
        doms = {}
        for r in ok:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        _csv("roofline_dominant_histogram",
             ";".join(f"{k}:{v}" for k, v in sorted(doms.items())))
    except Exception as e:
        _csv("roofline_pairs_baselined", 0, f"error:{type(e).__name__}")


if __name__ == "__main__":
    main()
