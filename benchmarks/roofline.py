"""Aggregate results/dryrun/*.json into the §Roofline table.

Per (arch × shape, single-pod): compute/memory/collective terms in seconds,
dominant term, MODEL_FLOPS/HLO_FLOPS utilization, and a one-line "what would
move the dominant term" note.

  PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--md]
"""
import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

NOTES = {
    ("compute", "train"): "raise arithmetic intensity: bf16 matmul paths already used; larger per-chip batch or fewer recomputes (remat policy)",
    ("compute", "prefill"): "quadratic attention dominates: sliding-window/block-sparse attention or more model-parallel heads",
    ("compute", "decode"): "matmul-bound decode: absorb projections (MLA) / fuse QKV; batch more requests per chip",
    ("memory", "train"): "activation traffic: bigger fusions (TPU) / fewer norm-precision casts; scan-block remat policy; grad-accum microbatching",
    ("memory", "prefill"): "score-tensor traffic: flash-attention kernel keeps softmax in VMEM (kernels/flash)",
    ("memory", "decode"): "KV-cache streaming bound: quantize cache to int8/bf16, MLA latent cache, sliding window",
    ("collective", "train"): "bigger FSDP unit (scan_block_size), bf16 gather/reduce-scatter instead of f32, overlap collectives with compute",
    ("collective", "prefill"): "TP all-reduce per layer: reduce-scatter+all-gather decomposition, sequence-parallel norms",
    ("collective", "decode"): "per-token psum/all-reduce latency-bound: fewer TP ranks for decode, batch tokens, bf16 reduces",
}


def load(mesh: str):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(path) as f:
            r = json.load(f)
        rows.append(r)
    return rows


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode"}[shape]


def fmt_row(r):
    if "skipped" in r:
        return None
    ct, mt, kt = (r["compute_term_s"], r["memory_term_s"],
                  r["collective_term_s"])
    dom = r["dominant_term"]
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "plan": r["plan"].split("(")[0],
        "compute_s": ct,
        "memory_s": mt,
        "collective_s": kt,
        "dominant": dom,
        "useful": r["useful_flops_ratio"],
        "note": NOTES[(dom, kind_of(r["shape"]))],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = [fmt_row(r) for r in load(args.mesh)]
    rows = [r for r in rows if r]
    if args.md:
        print("| arch | shape | plan | compute s | memory s | collective s "
              "| dominant | useful FLOP ratio |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['plan']} "
                  f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                  f"| {r['collective_s']:.3f} | **{r['dominant']}** "
                  f"| {r['useful']:.2f} |")
    else:
        print("arch,shape,plan,compute_s,memory_s,collective_s,dominant,useful")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['plan']},{r['compute_s']:.4f},"
                  f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
                  f"{r['useful']:.3f}")
    return rows


if __name__ == "__main__":
    main()
