"""Ablation sweep (the paper's raison d'être): vary ONE component of the
declarative setup — the sharding plan and the FSDP unit size — with zero code
changes, and compare compiled rooflines for the production mesh.

  PYTHONPATH=src python examples/ablation_sweep.py [--arch stablelm-1.6b]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun

    rows = []
    # ablation A: sharding plan
    for plan in ("ddp", "fsdp", "fsdp_tp"):
        r = dryrun(args.arch, args.shape, plan_name=plan, verbose=False)
        rows.append({
            "ablation": f"plan={plan}",
            "compute_s": round(r["compute_term_s"], 3),
            "memory_s": round(r["memory_term_s"], 3),
            "collective_s": round(r["collective_term_s"], 3),
            "dominant": r["dominant_term"],
        })
    # ablation B: FSDP unit size (scan block)
    for k in (1, 2, 4, 8):
        r = dryrun(args.arch, args.shape, plan_name="fsdp_tp", scan_block=k,
                   verbose=False)
        ag = r["collective_per_kind"]["all-gather"]
        rows.append({
            "ablation": f"fsdp_unit={k}",
            "collective_s": round(r["collective_term_s"], 3),
            "all_gather_bytes": int(ag),
            "n_all_gathers": r["collective_counts"]["all-gather"],
            "dominant": r["dominant_term"],
        })
    print(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
