"""Ablation sweep (the paper's raison d'être), now fully declarative: the
plan x FSDP-unit campaign lives in configs/ablation_dryrun.yaml; this driver
only loads the spec, runs it (resuming past completed trials), and prints the
ranked comparison table.

  PYTHONPATH=src python examples/ablation_sweep.py [--arch stablelm-1.6b] [--list]
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SPEC = os.path.join(os.path.dirname(__file__), "configs", "ablation_dryrun.yaml")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=SPEC, help="sweep YAML to run")
    ap.add_argument("--arch", default="", help="override the swept architecture")
    ap.add_argument("--shape", default="", help="override the input shape")
    ap.add_argument("--list", action="store_true",
                    help="show the expanded trials without running")
    args = ap.parse_args()

    from repro.run.cli import main as cli_main
    from repro.sweep.spec import SweepSpec, set_path

    def sweep_main(argv):
        return cli_main(["sweep", *argv])

    argv = ["--config", args.config]
    if args.list:
        argv.append("--list")
    if args.arch or args.shape:
        # override by patching the spec document the same way trials patch
        # the base config, then run from the rewritten spec
        import tempfile

        import yaml

        from repro.config.resolver import load_yaml
        from repro.sweep.spec import SweepError

        doc = load_yaml(args.config)
        sw = doc.get("sweep", doc)  # from_dict accepts both layouts
        if "base" not in sw:
            raise SweepError(
                "--arch/--shape overrides need an inline 'base' mapping in "
                f"{args.config} (specs using 'base_config' cannot be patched)")
        if args.arch:
            set_path(sw, "base.arch", args.arch, create_missing=True)
        if args.shape:
            set_path(sw, "base.shape", args.shape, create_missing=True)
        # re-key the sweep name + directory on the overrides so resume never
        # mistakes another configuration's records for this one
        tag = "ablation_" + "_".join(
            filter(None, [args.arch, args.shape])).replace("/", "-")
        sw["name"] = tag
        sw["output_dir"] = os.path.join("results", "sweeps", tag)
        SweepSpec.from_dict(doc)  # validate before writing the temp spec
        tmp = tempfile.NamedTemporaryFile(
            "w", suffix=".yaml", delete=False, prefix="ablation_sweep_")
        yaml.safe_dump(doc, tmp)
        tmp.close()
        argv[1] = tmp.name
    return sweep_main(argv)


if __name__ == "__main__":
    sys.exit(main())
