"""Quickstart: the whole training setup is the YAML dependency graph next to
this file; this script only resolves it and runs the gym (paper Fig. 1).

  PYTHONPATH=src python examples/quickstart.py [steps]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.core.components  # noqa: F401  (populates the component registry)
from repro.config.resolver import resolve_yaml


def main():
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 60
    cfg_path = os.path.join(os.path.dirname(__file__), "configs",
                            "quickstart.yaml")
    graph = resolve_yaml(cfg_path)
    out = graph["gym"].run(steps=steps)
    h = out["history"]
    print(f"quickstart: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"over {steps} steps")


if __name__ == "__main__":
    main()
