"""Quickstart: the whole training setup is the run document next to this
file; this script only hands it to the declarative Run API (paper Fig. 1).
Equivalent CLI:  python -m repro train --config examples/configs/quickstart.yaml

  PYTHONPATH=src python examples/quickstart.py [steps]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config.resolver import load_yaml
from repro.run import api as run_api
from repro.run.overrides import apply_overrides


def main():
    cfg_path = os.path.join(os.path.dirname(__file__), "configs",
                            "quickstart.yaml")
    doc = load_yaml(cfg_path)
    if len(sys.argv) > 1:
        doc = apply_overrides(doc, [("run.train.steps", int(sys.argv[1]))])
    out = run_api.execute_doc(doc, default_name="quickstart",
                              config_dir=os.path.dirname(cfg_path))
    print(f"quickstart: loss {out['first_loss']:.3f} -> "
          f"{out['final_loss']:.3f} over {out['steps']} steps "
          f"(artifact: {out['output_dir']})")


if __name__ == "__main__":
    main()
