"""End-to-end pretraining driver (deliverable b): raw JSONL corpus ->
indexation -> BPE tokenizer training -> producer-consumer tokenization ->
packed memmap dataset -> gym training (~hundreds of steps) -> checkpoint ->
HF-style export -> held-out perplexity.

  PYTHONPATH=src python examples/pretrain_e2e.py [--steps 300] [--d-model 256]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

WORK = "/tmp/repro_e2e"


def make_corpus(path: str, n_docs: int = 4000, seed: int = 0):
    """English-like template corpus with learnable structure."""
    rng = np.random.default_rng(seed)
    subjects = ["the model", "a tokenizer", "the optimizer", "the scheduler",
                "a dataset", "the framework", "the kernel", "an expert",
                "the router", "a checkpoint"]
    verbs = ["trains", "shards", "gathers", "reduces", "compiles", "scales",
             "streams", "routes", "caches", "converges"]
    objects = ["across the mesh", "over many pods", "with low latency",
               "under the roofline", "in bfloat16", "without stalls",
               "with a sliding window", "per expert", "at trillion tokens",
               "on every chip"]
    with open(path, "w") as f:
        for _ in range(n_docs):
            n_sent = int(rng.integers(2, 7))
            sents = []
            for _ in range(n_sent):
                s = f"{rng.choice(subjects)} {rng.choice(verbs)} {rng.choice(objects)}"
                sents.append(s)
            f.write(json.dumps({"text": ". ".join(sents) + "."}) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=6)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--merges", type=int, default=384)
    args = ap.parse_args()
    os.makedirs(WORK, exist_ok=True)

    from repro.data.indexer import index_jsonl
    from repro.data.packed_dataset import ChunkedLMDataset, PackedDataset, ShardedLoader
    from repro.data.tokenize_pipeline import tokenize_file
    from repro.data.tokenizer import BpeTokenizer
    from repro.models import build_model, count_params
    from repro.models.base import ArchConfig
    from repro.core.gym import Gym
    from repro.optim.adamw import AdamW
    from repro.optim.schedules import warmup_cosine
    from repro.train.checkpoint import export_flat, save_checkpoint

    # 1) corpus + indexation ------------------------------------------------
    corpus = os.path.join(WORK, "corpus.jsonl")
    if not os.path.exists(corpus):
        make_corpus(corpus)
    idx = index_jsonl(corpus)
    print(f"[1] indexed {len(idx)} documents", flush=True)

    # 2) tokenizer training ----------------------------------------------------
    tok_path = os.path.join(WORK, "bpe.json")
    if os.path.exists(tok_path):
        tok = BpeTokenizer.load(tok_path)
    else:
        sample = [json.loads(l)["text"] for l in open(corpus).readlines()[:300]]
        t0 = time.time()
        tok = BpeTokenizer.train(sample, n_merges=args.merges)
        tok.save(tok_path)
        print(f"[2] trained BPE ({tok.vocab_size} vocab) in "
              f"{time.time() - t0:.1f}s", flush=True)

    # 3) producer-consumer tokenization -> packed memmap ------------------------
    prefix = os.path.join(WORK, "packed")
    if not os.path.exists(prefix + ".tokens.u32"):
        t0 = time.time()
        info = tokenize_file(corpus, prefix, tok, n_workers=2)
        print(f"[3] tokenized {info['n_tokens']:,} tokens in "
              f"{time.time() - t0:.1f}s", flush=True)
    ds = PackedDataset(prefix)
    print(f"[3] packed dataset: {ds.n_docs} docs / {ds.n_tokens:,} tokens",
          flush=True)

    # 4) model + gym -------------------------------------------------------------
    cfg = ArchConfig(
        name="e2e-lm", arch_type="dense", n_layers=args.n_layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        d_ff=args.d_model * 4, vocab=tok.vocab_size, head_dim=32,
        scan_block_size=2,
    )
    model = build_model(cfg)
    chunked = ChunkedLMDataset(ds, args.seq_len, seed=0)
    n_train = int(len(chunked) * 0.95)
    loader = ShardedLoader(chunked, args.global_batch)
    gym = Gym(
        model=model,
        optimizer=AdamW(lr=warmup_cosine(1e-3, 30, args.steps)),
        loader=loader,
        log_every=20,
        logger=lambda m: print("[train]", json.dumps(m, default=float),
                               flush=True),
    )
    state = gym.setup()
    print(f"[4] model params: {count_params(state['params']):,}", flush=True)
    out = gym.run(args.steps, state=state)
    state = out["state"]

    # 5) checkpoint + HF-style export ---------------------------------------------
    import jax

    ck = save_checkpoint(jax.device_get(state), os.path.join(WORK, "ckpt"),
                         args.steps)
    ex = export_flat(jax.device_get(state["params"]),
                     os.path.join(WORK, "export"))
    print(f"[5] checkpoint: {ck}\n[5] HF-style export: {ex}", flush=True)

    # 6) held-out perplexity ---------------------------------------------------------
    import jax.numpy as jnp

    from repro.train.steps import compute_loss

    eval_losses = []
    for i in range(n_train, min(n_train + 20, len(chunked))):
        x, y = chunked.sample(i)
        loss, _ = compute_loss(
            model, state["params"],
            {"tokens": jnp.asarray(x)[None], "labels": jnp.asarray(y)[None]},
        )
        eval_losses.append(float(loss))
    ppl = float(np.exp(np.mean(eval_losses)))
    hist = out["history"]
    print(json.dumps({
        "first_train_loss": hist[0]["loss"],
        "last_train_loss": hist[-1]["loss"],
        "heldout_ppl": ppl,
        "heldout_loss": float(np.mean(eval_losses)),
        "uniform_baseline_loss": float(np.log(tok.vocab_size)),
    }, indent=2), flush=True)


if __name__ == "__main__":
    main()
