"""Serving demo (deliverable b): batched prefill + greedy decode with KV
cache, using the checkpoint produced by pretrain_e2e.py if present (otherwise
random weights).

  PYTHONPATH=src python examples/serve_demo.py [--gen 24]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

WORK = "/tmp/repro_e2e"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.data.tokenizer import BpeTokenizer
    from repro.models import build_model
    from repro.models.base import ArchConfig
    from repro.train.checkpoint import latest_checkpoint, restore_checkpoint
    from repro.train.steps import make_serve_step, init_train_state
    from repro.optim.adamw import AdamW

    tok_path = os.path.join(WORK, "bpe.json")
    have_ckpt = os.path.exists(tok_path) and latest_checkpoint(
        os.path.join(WORK, "ckpt"))
    if have_ckpt:
        tok = BpeTokenizer.load(tok_path)
        vocab = tok.vocab_size
    else:
        tok = None
        vocab = 512
    cfg = ArchConfig(
        name="e2e-lm", arch_type="dense", n_layers=6, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab=vocab, head_dim=32, scan_block_size=2,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if have_ckpt:
        step_no, path = have_ckpt
        state = init_train_state(model, AdamW(), jax.random.PRNGKey(0))
        state = restore_checkpoint(state, path)
        params = state["params"]
        print(f"loaded checkpoint step {step_no}")

    prompts = ["the model trains", "a tokenizer streams", "the router routes",
               "the optimizer"]
    B = len(prompts)
    if tok:
        ids = [tok.encode(p, bos=True) for p in prompts]
    else:
        ids = [[1, 5, 9, 12]] * B
    P = max(len(i) for i in ids)
    toks = jnp.asarray([[3] * (P - len(i)) + i for i in ids], jnp.int32)
    max_len = P + args.gen

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len)
    )(params, {"tokens": toks})
    print(f"prefill {B}x{P}: {time.time() - t0:.2f}s")

    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [cur]
    t0 = time.time()
    for i in range(args.gen - 1):
        cur, _, cache = serve(params, cache, cur,
                              jnp.full((B,), P + i, jnp.int32))
        outs.append(cur)
    dt = time.time() - t0
    gen = jnp.stack(outs, 1)
    print(f"decode {B}x{args.gen - 1}: {dt:.2f}s "
          f"({B * (args.gen - 1) / dt:.1f} tok/s)")
    for b in range(B):
        cont = tok.decode(gen[b].tolist()) if tok else str(gen[b].tolist())
        print(json.dumps({"prompt": prompts[b], "continuation": cont}))


if __name__ == "__main__":
    main()
